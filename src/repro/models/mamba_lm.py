"""Pure Mamba LM (the paper's evaluation models, Table 1: 130M..2.8B).

Homogeneous stack of Mamba blocks (residual, pre-norm), lax.scan over
stacked layer params, tied embeddings (as in the released Mamba family).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import state_quant
from repro.models import blocks, mamba
from repro.parallel.sharding import Param, constrain


def _layer_init(cfg, key):
    ks = jax.random.split(key, 2)
    return {"norm": blocks.norm_init(cfg, ks[0]),
            "mixer": mamba.mamba_block_init(cfg, ks[1])}


def _layer_apply(cfg, p, x, state=None, step=False):
    xn = blocks.apply_norm(cfg, p["norm"], x)
    if step:
        y, new_state = mamba.mamba_block_step(cfg, p["mixer"], xn, state)
    else:
        y, new_state = mamba.mamba_block_apply(cfg, p["mixer"], xn,
                                               state=state)
    x = x + y
    return constrain(x, "act_batch", "act_seq", "act_embed"), new_state


def init(cfg, key):
    ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    stacked = jax.vmap(lambda k: _layer_init(cfg, k))(layer_keys)
    stacked = jax.tree.map(
        lambda q: Param(q.value, ("layers",) + q.axes), stacked,
        is_leaf=lambda q: isinstance(q, Param))
    return {"embed": blocks.embed_init(cfg, ks[1]),
            "layers": stacked,
            "norm_f": blocks.norm_init(cfg, ks[2]),
            "unembed": blocks.unembed_init(cfg, ks[2])}


def forward(cfg, p, batch):
    dtype = jnp.dtype(cfg.dtype)
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    h = constrain(h, "act_batch", "act_seq", "act_embed")

    def body(x, lp):
        y, _ = _layer_apply(cfg, lp, x)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, p["layers"])
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    return logits, {}


def _quantized(cfg):
    return state_quant.is_quantized(cfg.state_dtype)


def init_cache(cfg, batch, max_seq, dtype):
    L = cfg.n_layers
    di, n, k = cfg.d_inner, cfg.d_state, cfg.d_conv
    out = {
        "h": Param(jnp.zeros((L, batch, di, n),
                             state_quant.storage_dtype(cfg.state_dtype)),
                   ("layers", "act_batch", "act_ffn", None)),
        "conv": Param(jnp.zeros((L, batch, k - 1, di), dtype),
                      ("layers", "act_batch", None, "act_ffn")),
        "pos": Param(jnp.zeros((batch,), jnp.int32), ("act_batch",)),
    }
    if _quantized(cfg):
        # per-slot-per-layer-per-channel-group f32 absmax scales live in
        # the cache pytree: gather/scatter/mask (and eviction's
        # fresh-state reset) move payload and scale together
        out["h_scale"] = Param(
            jnp.zeros((L, batch, state_quant.n_groups(di)), jnp.float32),
            ("layers", "act_batch", None))
    return out


def cache_slot_axes(cfg):
    """Batch/slot axis index per cache leaf (layout matches init_cache)."""
    ax = {"h": 1, "conv": 1, "pos": 0}
    if _quantized(cfg):
        ax["h_scale"] = 1
    return ax


def _pack_state(cfg, ns):
    """Per-layer state dict -> the lax.scan-stacked leaf tuple."""
    if _quantized(cfg):
        return (ns["h"], ns["h_scale"], ns["conv"])
    return (ns["h"], ns["conv"])


def _cache_from_stacked(cfg, stacked, pos):
    if _quantized(cfg):
        nh, nscale, nc = stacked
        return {"h": nh, "h_scale": nscale, "conv": nc, "pos": pos}
    nh, nc = stacked
    return {"h": nh, "conv": nc, "pos": pos}


# ---------------------------------------------------------------------------
# Self-speculative draft views: the draft model is the target's first
# ``n`` layers (embed / final norm / unembed shared), so a draft needs no
# second parameter set — just a slice of the stacked layer leaves, and a
# matching slice of the pooled cache that merges back leaf-for-leaf.
# ---------------------------------------------------------------------------

def draft_params(cfg, p, n):
    """First-``n``-layers view of a (plain-value) param tree."""
    return {**p, "layers": jax.tree.map(lambda q: q[:n], p["layers"])}


def draft_cache(cfg, cache, n):
    """First-``n``-layers view of a pooled cache (pos shared)."""
    keys = ["h", "conv"] + (["h_scale"] if _quantized(cfg) else [])
    out = {k: cache[k][:n] for k in keys}
    out["pos"] = cache["pos"]
    return out


def draft_cache_merge(cfg, full, sub, n):
    """Write a draft-updated first-``n``-layers cache back into the full
    cache (the inverse of draft_cache; layers >= n untouched)."""
    keys = ["h", "conv"] + (["h_scale"] if _quantized(cfg) else [])
    out = {k: full[k].at[:n].set(sub[k]) for k in keys}
    out["pos"] = sub["pos"]
    return out


def stacked_step(cfg, p, cache, batch):
    """Single-token decode as ONE Pallas launch for the whole stack.

    The layer loop that ``decode_step`` runs as a lax.scan of per-layer
    launches becomes the kernel grid: stacked layer params and the
    pooled recurrent cache ride in with a leading L axis, the residual
    stream is carried in a revisited output block, and each grid step
    runs norm -> mamba megastep -> residual for its layer.  Embed and
    the final norm/unembed stay in XLA — exactly one pallas_call per
    decoded token."""
    from repro.kernels import decode_step as dsk
    dtype = jnp.dtype(cfg.dtype)
    x0 = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    x0 = constrain(x0, "act_batch", None, "act_embed")
    quant = _quantized(cfg)

    stacked_in = {"p": p["layers"], "h": cache["h"], "conv": cache["conv"]}
    if quant:
        stacked_in["h_scale"] = cache["h_scale"]

    def body(x, ins):
        state = {"h": ins["h"], "conv": ins["conv"]}
        if quant:
            state["h_scale"] = ins["h_scale"]
        xn = blocks.apply_norm(cfg, ins["p"]["norm"], x)
        y, ns = mamba.mamba_block_megastep(cfg, ins["p"]["mixer"], xn,
                                           state)
        x = constrain(x + y, "act_batch", "act_seq", "act_embed")
        return x, _pack_state(cfg, ns)

    b = cache["h"].shape[1]
    di, n, k = cfg.d_inner, cfg.d_state, cfg.d_conv
    storage = state_quant.storage_dtype(cfg.state_dtype)
    out_structs = [jax.ShapeDtypeStruct((b, di, n), storage)]
    if quant:
        out_structs.append(jax.ShapeDtypeStruct(
            (b, state_quant.n_groups(di)), jnp.float32))
    out_structs.append(
        jax.ShapeDtypeStruct((b, k - 1, di), cache["conv"].dtype))

    h, stacked = dsk.stacked_layer_launch(
        body, x0, stacked_in, out_structs, name="marca_megakernel_mamba")
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    return logits, _cache_from_stacked(cfg, stacked, cache["pos"] + 1)


def decode_step(cfg, p, cache, batch):
    from repro.core.selective_scan import resolve_step_impl
    if resolve_step_impl(cfg.step_impl) == "megakernel":
        return stacked_step(cfg, p, cache, batch)
    dtype = jnp.dtype(cfg.dtype)
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    h = constrain(h, "act_batch", None, "act_embed")
    quant = _quantized(cfg)

    def body(x, lp_state):
        if quant:
            lp, hs, ss, cs = lp_state
            state = {"h": hs, "h_scale": ss, "conv": cs}
        else:
            lp, hs, cs = lp_state
            state = {"h": hs, "conv": cs}
        y, ns = _layer_apply(cfg, lp, x, state=state, step=True)
        return y, _pack_state(cfg, ns)

    xs = ((p["layers"], cache["h"], cache["h_scale"], cache["conv"])
          if quant else (p["layers"], cache["h"], cache["conv"]))
    h, stacked = jax.lax.scan(body, h, xs)
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    return logits, _cache_from_stacked(cfg, stacked, cache["pos"] + 1)


def verify_window(cfg, p, cache, tokens):
    """Spec-decode verify over a K-token window through the batched
    block front-ends: ONE embed + per-layer ``mamba_block_verify``
    (projections/conv/dt over the whole window, SSM recurrence as the
    K-step micro-scan) instead of K chained ``decode_step`` calls.
    Token-stream equivalence to the chained path rests on XLA's
    row-wise GEMM determinism: a (b, K, d) matmul computes each row
    exactly as the (b, 1, d) one does.

    tokens (b, K) int32.  Returns (logits (b, K, V), caches) in the
    chained verify_scan layout: cache pytree with a leading per-step
    axis (caches[t] = cache after consuming tokens[:, t])."""
    dtype = jnp.dtype(cfg.dtype)
    K = tokens.shape[1]
    x = blocks.embed_apply(cfg, p["embed"], tokens, dtype)
    x = constrain(x, "act_batch", "act_seq", "act_embed")
    quant = _quantized(cfg)

    def body(x, lp_state):
        if quant:
            lp, hs, ss, cs = lp_state
            state = {"h": hs, "h_scale": ss, "conv": cs}
        else:
            lp, hs, cs = lp_state
            state = {"h": hs, "conv": cs}
        xn = blocks.apply_norm(cfg, lp["norm"], x)
        y, states = mamba.mamba_block_verify(cfg, lp["mixer"], xn, state)
        x = constrain(x + y, "act_batch", "act_seq", "act_embed")
        return x, _pack_state(cfg, states)

    xs = ((p["layers"], cache["h"], cache["h_scale"], cache["conv"])
          if quant else (p["layers"], cache["h"], cache["conv"]))
    x, stacked = jax.lax.scan(body, x, xs)
    x = blocks.apply_norm(cfg, p["norm_f"], x)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], x)
    # scan stacks L leading and block_verify stacks steps on axis 1 of
    # (b, K, ...): (L, b, K, ...) -> the chained layout (K, L, b, ...)
    stacked = jax.tree.map(lambda t: jnp.moveaxis(t, 2, 0), stacked)
    pos = (cache["pos"][None, :]
           + jnp.arange(1, K + 1, dtype=jnp.int32)[:, None])
    return logits, _cache_from_stacked(cfg, stacked, pos)


def prefill(cfg, p, cache, batch):
    """Full-sequence forward that also returns the decode cache."""
    dtype = jnp.dtype(cfg.dtype)
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    h = constrain(h, "act_batch", "act_seq", "act_embed")

    def body(x, lp):
        y, ns = _layer_apply(cfg, lp, x)
        return y, _pack_state(cfg, ns)

    h, stacked = jax.lax.scan(body, h, p["layers"])
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    b = h.shape[0]
    pos = jnp.full((b,), batch["tokens"].shape[1], jnp.int32)
    return logits, _cache_from_stacked(cfg, stacked, pos)
