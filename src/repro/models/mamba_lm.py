"""Pure Mamba LM (the paper's evaluation models, Table 1: 130M..2.8B).

Homogeneous stack of Mamba blocks (residual, pre-norm), lax.scan over
stacked layer params, tied embeddings (as in the released Mamba family).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks, mamba
from repro.parallel.sharding import Param, constrain


def _layer_init(cfg, key):
    ks = jax.random.split(key, 2)
    return {"norm": blocks.norm_init(cfg, ks[0]),
            "mixer": mamba.mamba_block_init(cfg, ks[1])}


def _layer_apply(cfg, p, x, state=None, step=False):
    xn = blocks.apply_norm(cfg, p["norm"], x)
    if step:
        y, new_state = mamba.mamba_block_step(cfg, p["mixer"], xn, state)
    else:
        y, new_state = mamba.mamba_block_apply(cfg, p["mixer"], xn,
                                               state=state)
    x = x + y
    return constrain(x, "act_batch", "act_seq", "act_embed"), new_state


def init(cfg, key):
    ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    stacked = jax.vmap(lambda k: _layer_init(cfg, k))(layer_keys)
    stacked = jax.tree.map(
        lambda q: Param(q.value, ("layers",) + q.axes), stacked,
        is_leaf=lambda q: isinstance(q, Param))
    return {"embed": blocks.embed_init(cfg, ks[1]),
            "layers": stacked,
            "norm_f": blocks.norm_init(cfg, ks[2]),
            "unembed": blocks.unembed_init(cfg, ks[2])}


def forward(cfg, p, batch):
    dtype = jnp.dtype(cfg.dtype)
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    h = constrain(h, "act_batch", "act_seq", "act_embed")

    def body(x, lp):
        y, _ = _layer_apply(cfg, lp, x)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, p["layers"])
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    return logits, {}


def init_cache(cfg, batch, max_seq, dtype):
    L = cfg.n_layers
    di, n, k = cfg.d_inner, cfg.d_state, cfg.d_conv
    return {
        "h": Param(jnp.zeros((L, batch, di, n), jnp.float32),
                   ("layers", "act_batch", "act_ffn", None)),
        "conv": Param(jnp.zeros((L, batch, k - 1, di), dtype),
                      ("layers", "act_batch", None, "act_ffn")),
        "pos": Param(jnp.zeros((batch,), jnp.int32), ("act_batch",)),
    }


def cache_slot_axes(cfg):
    """Batch/slot axis index per cache leaf (layout matches init_cache)."""
    return {"h": 1, "conv": 1, "pos": 0}


def decode_step(cfg, p, cache, batch):
    dtype = jnp.dtype(cfg.dtype)
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    h = constrain(h, "act_batch", None, "act_embed")

    def body(x, lp_state):
        lp, hs, cs = lp_state
        y, ns = _layer_apply(cfg, lp, x, state={"h": hs, "conv": cs},
                             step=True)
        return y, (ns["h"], ns["conv"])

    h, (nh, nc) = jax.lax.scan(body, h, (p["layers"], cache["h"],
                                         cache["conv"]))
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    return logits, {"h": nh, "conv": nc, "pos": cache["pos"] + 1}


def prefill(cfg, p, cache, batch):
    """Full-sequence forward that also returns the decode cache."""
    dtype = jnp.dtype(cfg.dtype)
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    h = constrain(h, "act_batch", "act_seq", "act_embed")

    def body(x, lp):
        y, ns = _layer_apply(cfg, lp, x)
        return y, (ns["h"], ns["conv"])

    h, (hs, cs) = jax.lax.scan(body, h, p["layers"])
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    b = h.shape[0]
    pos = jnp.full((b,), batch["tokens"].shape[1], jnp.int32)
    return logits, {"h": hs, "conv": cs, "pos": pos}
