"""Pure Mamba LM (the paper's evaluation models, Table 1: 130M..2.8B).

Homogeneous stack of Mamba blocks (residual, pre-norm), lax.scan over
stacked layer params, tied embeddings (as in the released Mamba family).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import state_quant
from repro.models import blocks, mamba
from repro.parallel.sharding import Param, constrain


def _layer_init(cfg, key):
    ks = jax.random.split(key, 2)
    return {"norm": blocks.norm_init(cfg, ks[0]),
            "mixer": mamba.mamba_block_init(cfg, ks[1])}


def _layer_apply(cfg, p, x, state=None, step=False):
    xn = blocks.apply_norm(cfg, p["norm"], x)
    if step:
        y, new_state = mamba.mamba_block_step(cfg, p["mixer"], xn, state)
    else:
        y, new_state = mamba.mamba_block_apply(cfg, p["mixer"], xn,
                                               state=state)
    x = x + y
    return constrain(x, "act_batch", "act_seq", "act_embed"), new_state


def init(cfg, key):
    ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    stacked = jax.vmap(lambda k: _layer_init(cfg, k))(layer_keys)
    stacked = jax.tree.map(
        lambda q: Param(q.value, ("layers",) + q.axes), stacked,
        is_leaf=lambda q: isinstance(q, Param))
    return {"embed": blocks.embed_init(cfg, ks[1]),
            "layers": stacked,
            "norm_f": blocks.norm_init(cfg, ks[2]),
            "unembed": blocks.unembed_init(cfg, ks[2])}


def forward(cfg, p, batch):
    dtype = jnp.dtype(cfg.dtype)
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    h = constrain(h, "act_batch", "act_seq", "act_embed")

    def body(x, lp):
        y, _ = _layer_apply(cfg, lp, x)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, p["layers"])
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    return logits, {}


def _quantized(cfg):
    return state_quant.is_quantized(cfg.state_dtype)


def init_cache(cfg, batch, max_seq, dtype):
    L = cfg.n_layers
    di, n, k = cfg.d_inner, cfg.d_state, cfg.d_conv
    out = {
        "h": Param(jnp.zeros((L, batch, di, n),
                             state_quant.storage_dtype(cfg.state_dtype)),
                   ("layers", "act_batch", "act_ffn", None)),
        "conv": Param(jnp.zeros((L, batch, k - 1, di), dtype),
                      ("layers", "act_batch", None, "act_ffn")),
        "pos": Param(jnp.zeros((batch,), jnp.int32), ("act_batch",)),
    }
    if _quantized(cfg):
        # per-slot-per-layer-per-channel-group f32 absmax scales live in
        # the cache pytree: gather/scatter/mask (and eviction's
        # fresh-state reset) move payload and scale together
        out["h_scale"] = Param(
            jnp.zeros((L, batch, state_quant.n_groups(di)), jnp.float32),
            ("layers", "act_batch", None))
    return out


def cache_slot_axes(cfg):
    """Batch/slot axis index per cache leaf (layout matches init_cache)."""
    ax = {"h": 1, "conv": 1, "pos": 0}
    if _quantized(cfg):
        ax["h_scale"] = 1
    return ax


def _pack_state(cfg, ns):
    """Per-layer state dict -> the lax.scan-stacked leaf tuple."""
    if _quantized(cfg):
        return (ns["h"], ns["h_scale"], ns["conv"])
    return (ns["h"], ns["conv"])


def _cache_from_stacked(cfg, stacked, pos):
    if _quantized(cfg):
        nh, nscale, nc = stacked
        return {"h": nh, "h_scale": nscale, "conv": nc, "pos": pos}
    nh, nc = stacked
    return {"h": nh, "conv": nc, "pos": pos}


# ---------------------------------------------------------------------------
# Self-speculative draft views: the draft model is the target's first
# ``n`` layers (embed / final norm / unembed shared), so a draft needs no
# second parameter set — just a slice of the stacked layer leaves, and a
# matching slice of the pooled cache that merges back leaf-for-leaf.
# ---------------------------------------------------------------------------

def draft_params(cfg, p, n):
    """First-``n``-layers view of a (plain-value) param tree."""
    return {**p, "layers": jax.tree.map(lambda q: q[:n], p["layers"])}


def draft_cache(cfg, cache, n):
    """First-``n``-layers view of a pooled cache (pos shared)."""
    keys = ["h", "conv"] + (["h_scale"] if _quantized(cfg) else [])
    out = {k: cache[k][:n] for k in keys}
    out["pos"] = cache["pos"]
    return out


def draft_cache_merge(cfg, full, sub, n):
    """Write a draft-updated first-``n``-layers cache back into the full
    cache (the inverse of draft_cache; layers >= n untouched)."""
    keys = ["h", "conv"] + (["h_scale"] if _quantized(cfg) else [])
    out = {k: full[k].at[:n].set(sub[k]) for k in keys}
    out["pos"] = sub["pos"]
    return out


def decode_step(cfg, p, cache, batch):
    dtype = jnp.dtype(cfg.dtype)
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    h = constrain(h, "act_batch", None, "act_embed")
    quant = _quantized(cfg)

    def body(x, lp_state):
        if quant:
            lp, hs, ss, cs = lp_state
            state = {"h": hs, "h_scale": ss, "conv": cs}
        else:
            lp, hs, cs = lp_state
            state = {"h": hs, "conv": cs}
        y, ns = _layer_apply(cfg, lp, x, state=state, step=True)
        return y, _pack_state(cfg, ns)

    xs = ((p["layers"], cache["h"], cache["h_scale"], cache["conv"])
          if quant else (p["layers"], cache["h"], cache["conv"]))
    h, stacked = jax.lax.scan(body, h, xs)
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    return logits, _cache_from_stacked(cfg, stacked, cache["pos"] + 1)


def prefill(cfg, p, cache, batch):
    """Full-sequence forward that also returns the decode cache."""
    dtype = jnp.dtype(cfg.dtype)
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    h = constrain(h, "act_batch", "act_seq", "act_embed")

    def body(x, lp):
        y, ns = _layer_apply(cfg, lp, x)
        return y, _pack_state(cfg, ns)

    h, stacked = jax.lax.scan(body, h, p["layers"])
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    b = h.shape[0]
    pos = jnp.full((b,), batch["tokens"].shape[1], jnp.int32)
    return logits, _cache_from_stacked(cfg, stacked, pos)
