"""Mixture-of-Experts FFN: top-k router with capacity, sort-free scatter
dispatch, shared experts (qwen2-moe) and dense residual (arctic).

Expert weights are sharded over the "expert" logical axis (EP over the mesh
"model" axis) and over "embed" (FSDP over "data"); dispatch/combine are
scatter/gather einsums whose cross-device movement GSPMD lowers to
all-to-all/all-gather — visible in the dry-run collective table.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import approx
from repro.models import blocks
from repro.parallel.sharding import Param, constrain


def _e_padded(cfg):
    return max(cfg.expert_pad_to, cfg.n_experts)


def moe_init(cfg, key, d_ff=None):
    d, E = cfg.d_model, _e_padded(cfg)
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    sc = d ** -0.5

    def ew(k, shape, axes):
        return Param(jax.random.normal(k, shape, jnp.float32) * sc, axes)

    p = {
        "router": blocks.dense_init(ks[0], d, E, ("embed", None)),  # E = padded
        "w1": ew(ks[1], (E, d, f), ("expert", "embed", None)),
        "w3": ew(ks[2], (E, d, f), ("expert", "embed", None)),
        "w2": ew(ks[3], (E, f, d), ("expert", None, "embed")),
    }
    if cfg.n_shared_experts:
        p["shared"] = blocks.mlp_init(cfg, ks[4],
                                      d_ff=cfg.n_shared_experts * f)
    return p


def _capacity(cfg, n_tokens):
    cap = int(n_tokens * cfg.top_k * cfg.capacity_factor
              // max(cfg.n_experts, 1))
    return max(cap, cfg.top_k, 1)


def moe_apply(cfg, p, x):
    """x (b, s, d) -> (y (b, s, d), aux dict with load-balance/z losses).

    Dispatch implementation per cfg.moe_impl: "dense" = pjit-auto
    scatter/gather; "ep" = explicit shard_map all-to-all (requires an
    active mesh with a model axis; §Perf Q5); "auto" = ep when available.
    """
    if cfg.moe_impl in ("auto", "ep"):
        mesh = _ep_available(cfg, x.shape[1])
        if mesh is not None:
            return moe_apply_ep(cfg, p, x, mesh)
        if cfg.moe_impl == "ep":
            raise RuntimeError("moe_impl='ep' needs a mesh with a 'model' "
                               "axis and divisible seq/experts")
    silu = approx.get_silu(cfg.silu_impl)
    b, s, d = x.shape
    E, k = _e_padded(cfg), cfg.top_k
    T = b * s
    cap = _capacity(cfg, T)
    xf = x.reshape(T, d)

    logits = blocks.dense(p["router"], xf.astype(jnp.float32),
                          jnp.float32)                    # (T, E_pad)
    if E > cfg.n_experts:
        # padded experts are inert: forced out of the top-k
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                   # (T, k)
    if cfg.norm_topk:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # --- aux losses (GShard/Switch load balance + router z-loss) ---
    me = probs.mean(0)                                    # (E,)
    assign = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32).mean(0)
    aux_lb = cfg.n_experts * jnp.sum(me * assign)
    aux_z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"moe_lb": aux_lb * cfg.router_aux_coef,
           "moe_z": aux_z * cfg.router_z_coef}

    # --- capacity-based dispatch (position = rank within expert) ---
    e_flat = idx.reshape(-1)                              # (T*k,)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)   # (T*k, E)
    pos_all = jnp.cumsum(onehot, axis=0) - 1              # (T*k, E)
    pos = jnp.take_along_axis(pos_all, e_flat[:, None], 1)[:, 0]
    keep = pos < cap                                      # (T*k,)
    # overflow assignments scatter zeros into slot 0 / gather from slot 0
    # and are masked by `keep` — no dump row, no whole-tensor concatenate
    # (the concat was replicated: ~1 TB/chip; EXPERIMENTS.md §Perf Q2)
    slot = jnp.where(keep, e_flat * cap + pos, 0)

    xrep = jnp.repeat(xf, k, axis=0)                      # (T*k, d)
    buckets = jnp.zeros((E * cap, d), x.dtype).at[slot].add(
        jnp.where(keep[:, None], xrep, 0))
    buckets = buckets.reshape(E, cap, d)
    # EP x DP: experts over "model", capacity slots over ("pod","data") —
    # without the capacity sharding every data-shard chip computes the SAME
    # expert at full capacity (16x redundant FLOPs; caught by the roofline
    # useful/HLO ratio, see EXPERIMENTS.md §Perf iteration J2).
    buckets = constrain(buckets, "act_expert", "act_batch", None)

    # --- expert computation (batched swiglu) ---
    cdt = x.dtype
    h = jnp.einsum("ecd,edf->ecf", buckets,
                   p["w1"].astype(cdt))
    h = silu(h) * jnp.einsum("ecd,edf->ecf", buckets,
                             p["w3"].astype(cdt))
    h = constrain(h, "act_expert", "act_batch", None)
    y_b = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(cdt))
    y_flat = y_b.reshape(E * cap, d)

    # --- combine: gather back, weight by gate, sum over k ---
    y_tok = y_flat[slot]                                  # (T*k, d)
    gflat = (gate.reshape(-1) * keep).astype(cdt)         # (T*k,)
    y = (y_tok * gflat[:, None]).reshape(T, k, d).sum(1)

    y = y.reshape(b, s, d)
    if cfg.n_shared_experts:
        y = y + blocks.mlp_apply(cfg, p["shared"], x)
    return y, aux


# ---------------------------------------------------------------------------
# Explicit expert-parallel dispatch (shard_map all-to-all), §Perf Q5.
#
# The pjit-auto dispatch above lets GSPMD resolve the computed-index
# gather/scatter between token-sharded and expert-sharded layouts; it does
# so with masked (T*k, d) all-reduces over the model axis per MoE layer
# (~740 GB/chip on qwen2-moe train_4k).  The production pattern moves each
# token row exactly once: tokens are sequence-sharded over `model` inside
# the layer, each chip dispatches its local tokens into per-expert capacity
# buckets, one tiled all_to_all over `model` routes buckets to their expert
# owners, expert GEMMs run local, and the reverse all_to_all brings results
# home.  Capacity becomes per-(data-shard, expert) — standard EP semantics
# (dropping pattern differs from the global-capacity dense path; tests
# compare at no-drop capacity).
# ---------------------------------------------------------------------------

def _ep_available(cfg, s):
    from repro.parallel import sharding as shd
    mesh = shd._CTX["mesh"]
    if mesh is None or "model" not in mesh.axis_names:
        return None
    P_model = mesh.shape["model"]
    E = _e_padded(cfg)
    if E % P_model or s % P_model:
        return None
    return mesh


def _moe_local(cfg, w1, w3, w2, router, xloc, *, axis: str,
               stat_axes: tuple):
    """Runs inside shard_map.  xloc (tloc, d) local tokens; router (d, E);
    w1/w3 (e_loc, d, f); w2 (e_loc, f, d).  stat_axes: all mesh axes (aux
    statistics are reduced globally so they replicate)."""
    silu = approx.get_silu(cfg.silu_impl)
    E, k = _e_padded(cfg), cfg.top_k
    P = jax.lax.psum(1, axis)
    tloc, d = xloc.shape
    cap = max(int(tloc * k * cfg.capacity_factor // E), 1)

    logits = (xloc.astype(jnp.float32) @ router)          # (tloc, E)
    if E > cfg.n_experts:
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    if cfg.norm_topk:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    e_flat = idx.reshape(-1)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - 1,
                              e_flat[:, None], 1)[:, 0]
    keep = pos < cap
    slot = jnp.where(keep, e_flat * cap + pos, 0)
    xrep = jnp.repeat(xloc, k, axis=0)
    buckets = jnp.zeros((E * cap, d), xloc.dtype).at[slot].add(
        jnp.where(keep[:, None], xrep, 0)).reshape(E, cap, d)

    # route buckets to expert owners: (E, cap, d) -> (E/P, P*cap, d)
    routed = jax.lax.all_to_all(buckets, axis, split_axis=0,
                                concat_axis=1, tiled=True)
    cdt = xloc.dtype
    h = jnp.einsum("ecd,edf->ecf", routed, w1.astype(cdt))
    h = silu(h) * jnp.einsum("ecd,edf->ecf", routed, w3.astype(cdt))
    y_r = jnp.einsum("ecf,efd->ecd", h, w2.astype(cdt))
    # route results home: (E/P, P*cap, d) -> (E, cap, d)
    y_b = jax.lax.all_to_all(y_r, axis, split_axis=1, concat_axis=0,
                             tiled=True).reshape(E * cap, d)

    y_tok = y_b[slot] * (gate.reshape(-1) * keep).astype(cdt)[:, None]
    y = y_tok.reshape(tloc, k, d).sum(1)

    # aux losses: token statistics reduced over the WHOLE mesh (replicated)
    n_tok = jax.lax.psum(jnp.float32(tloc), stat_axes)
    me = jax.lax.psum(probs.sum(0), stat_axes) / n_tok
    assign = jax.lax.psum(
        jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32).sum(0),
        stat_axes) / n_tok
    aux_lb = cfg.n_experts * jnp.sum(me * assign)
    aux_z = jax.lax.psum(
        (jax.nn.logsumexp(logits, axis=-1) ** 2).sum(), stat_axes) / n_tok
    return y, aux_lb, aux_z


def moe_apply_ep(cfg, p, x, mesh):
    """Expert-parallel MoE via shard_map (tokens seq-sharded over `model`)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    b, s, d = x.shape
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    xs = P(batch_axes if batch_axes else None, "model", None)

    stat_axes = tuple(mesh.axis_names)

    def wrapped(w1, w3, w2, router, xloc):
        bl, sl, dl = xloc.shape
        y, lb, z = _moe_local(cfg, w1, w3, w2, router,
                              xloc.reshape(bl * sl, dl), axis="model",
                              stat_axes=stat_axes)
        return (y.reshape(bl, sl, dl), lb, z)

    fn = shard_map(
        wrapped, mesh=mesh,
        in_specs=(P("model", None, None), P("model", None, None),
                  P("model", None, None), P(None, None), xs),
        out_specs=(xs, P(), P()),
    )
    y, lb, z = fn(p["w1"], p["w3"], p["w2"], p["router"]["w"], x)
    aux = {"moe_lb": lb * cfg.router_aux_coef,
           "moe_z": z * cfg.router_z_coef}
    if cfg.n_shared_experts:
        y = y + blocks.mlp_apply(cfg, p["shared"], x)
    return y, aux
