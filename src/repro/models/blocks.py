"""Shared model building blocks: norms, rotary, GQA attention (chunked
online computation for 32k prefill), gated MLPs, embeddings.

All params are ``sharding.Param(value, logical_axes)`` leaves; all functions
are pure.  Compute dtype follows cfg.dtype, accumulation/softmax in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import approx
from repro.kernels import ops
from repro.parallel.sharding import Param, constrain

# ---------------------------------------------------------------------------
# Param helpers
# ---------------------------------------------------------------------------


def _split(key, n):
    return jax.random.split(key, n)


def dense_init(key, d_in, d_out, axes, bias=False, dtype=jnp.float32,
               scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": Param(jax.random.normal(key, (d_in, d_out), dtype) * scale,
                    axes)}
    if bias:
        p["b"] = Param(jnp.zeros((d_out,), dtype), (axes[1],))
    return p


def dense(p, x, compute_dtype=None):
    """Apply-time: p is a PLAIN value tree (Params stripped by registry).

    When ``p`` carries a ``w_scale`` sibling (cfg.weight_dtype="int8"),
    ``w`` holds per-output-channel int8 codes and is dequantized HERE —
    at the point of consumption.  The megakernel bodies call this inside
    their Pallas launch, so for the cross-layer decode path the int8 ->
    f32 expansion happens in-kernel on the grid-local (per-layer) weight
    block; the XLA reference and prefill paths run the identical scale
    multiply, keeping all step impls on one scale math."""
    w = p["w"]
    if "w_scale" in p:
        w = w.astype(jnp.float32) * p["w_scale"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(cfg, key=None):
    d = cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": Param(jnp.ones((d,), jnp.float32), ("embed",))}
    if cfg.norm == "ln":
        return {"scale": Param(jnp.ones((d,), jnp.float32), ("embed",)),
                "bias": Param(jnp.zeros((d,), jnp.float32), ("embed",))}
    if cfg.norm == "ln_nonparam":          # olmo: no affine params
        return {}
    raise ValueError(cfg.norm)


def apply_norm(cfg, p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        xf = xf * p["scale"]
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps)
        if cfg.norm == "ln":
            xf = xf * p["scale"] + p["bias"]
    return xf.astype(x.dtype)


def group_norm(x, scale, n_groups, eps=1e-5):
    """x (..., d); per-group normalization (xLSTM head norm)."""
    shp = x.shape
    xf = x.astype(jnp.float32).reshape(*shp[:-1], n_groups, -1)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    xf = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(shp)
    return (xf * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x, positions, theta):
    """x (b, l, h, dh); positions (b, l) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = (theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs   # (b, l, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA + rotary + optional bias), cache-aware
# ---------------------------------------------------------------------------

def attention_init(cfg, key):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = _split(key, 4)
    return {
        "wq": dense_init(ks[0], d, hq * dh, ("embed", "heads"),
                         bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, hkv * dh, ("embed", "kv"),
                         bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, hkv * dh, ("embed", "kv"),
                         bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], hq * dh, d, ("heads", "embed")),
    }


def _grouped_scores(q, k, scale):
    """q (b,lq,hkv,rep,dh), k (b,lk,hkv,dh) -> (b,hkv,rep,lq,lk) f32.

    Inputs stay in their storage dtype (bf16): the MXU accumulates in f32
    via preferred_element_type — half the stream bytes and bf16 cotangents
    (EXPERIMENTS.md §Perf Q3)."""
    return jnp.einsum("bqgrd,bkgd->bgrqk", q, k,
                      preferred_element_type=jnp.float32) * scale


def chunked_causal_attention(q, k, v, chunk=512, q_offset=0):
    """Memory-bounded causal attention: scan over query chunks, scores kept
    at (chunk x lk), grouped-head einsums (no kv repetition).  Differentiable
    and GSPMD-friendly; used for prefill_32k.  q (b,lq,hq,dh),
    k/v (b,lk,hkv,dh)."""
    b, lq, hq, dh = q.shape
    lk = k.shape[1]
    hkv = k.shape[2]
    rep = hq // hkv
    scale = dh ** -0.5
    chunk = min(chunk, lq)
    pad = (-lq) % chunk
    nq = (lq + pad) // chunk
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = qp.reshape(b, nq, chunk, hkv, rep, dh).swapaxes(0, 1)
    kcols = jnp.arange(lk)

    def one(ci, qc):
        s = _grouped_scores(qc, k, scale)                  # (b,g,r,cq,lk)
        rows = q_offset + ci * chunk + jnp.arange(chunk)
        mask = rows[:, None] >= kcols[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bgrqk,bkgd->bqgrd", p.astype(v.dtype), v,
                          preferred_element_type=jnp.float32
                          ).astype(q.dtype)

    one_ck = jax.checkpoint(one, static_argnums=())

    def body(_, inp):
        ci, qc = inp
        return None, one_ck(ci, qc)

    _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qg))
    o = outs.swapaxes(0, 1).reshape(b, nq * chunk, hq, dh)
    return o[:, :lq]


def decode_attention(q, k_cache, v_cache, pos):
    """Single-token attention over a cache.  q (b,1,hq,dh);
    k/v_cache (b,S,hkv,dh); pos (b,) index of the query token."""
    b, _, hq, dh = q.shape
    hkv = k_cache.shape[2]
    rep = hq // hkv
    scale = dh ** -0.5
    qg = q.reshape(b, 1, hkv, rep, dh)
    s = _grouped_scores(qg, k_cache, scale)            # (b,g,r,1,S)
    cols = jnp.arange(k_cache.shape[1])
    mask = cols[None, :] <= pos[:, None]               # (b,S)
    s = jnp.where(mask[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, hq, dh).astype(q.dtype)


def _kv_quant(t):
    """(b, l, hkv*dh) -> int8 payload + per-(b,l) f32 absmax scale."""
    scale = jnp.maximum(jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1,
                                keepdims=True), 1e-6) / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def attention_apply(cfg, p, x, positions, cache=None, pos=None,
                    return_kv=False):
    """cache: dict(k (b,S,hkv*dh), v (b,S,hkv*dh)) flat-layout (+ k_scale /
    v_scale when cfg.kv_cache_dtype == "int8"); pos (b,).
    return_kv: full-seq path also returns the rotated (k, v) flat tensors
    (prefill cache fill)."""
    b, l, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = x.dtype
    q = dense(p["wq"], x, cdt).reshape(b, l, hq, dh)
    k = dense(p["wk"], x, cdt).reshape(b, l, hkv, dh)
    v = dense(p["wv"], x, cdt).reshape(b, l, hkv, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    new_cache = None
    if cache is not None:
        # write the new kv at per-batch position pos, then attend over cache
        S = cache["k"].shape[1]
        onehot = jnp.arange(S)[None, :] == pos[:, None]      # (b,S)
        quantized = cfg.kv_cache_dtype == "int8"
        if quantized:
            kq, ks = _kv_quant(k.reshape(b, l, hkv * dh))   # (b,1,D),(b,1,1)
            vq, vs = _kv_quant(v.reshape(b, l, hkv * dh))
            kcq = jnp.where(onehot[..., None], kq, cache["k"])
            vcq = jnp.where(onehot[..., None], vq, cache["v"])
            kss = jnp.where(onehot[..., None], ks, cache["k_scale"])
            vss = jnp.where(onehot[..., None], vs, cache["v_scale"])
            kc = _kv_dequant(kcq, kss, cdt).reshape(b, S, hkv, dh)
            vc = _kv_dequant(vcq, vss, cdt).reshape(b, S, hkv, dh)
            new_cache = {"k": kcq, "v": vcq,
                         "k_scale": kss, "v_scale": vss}
        else:
            kc = jnp.where(onehot[..., None, None],
                           k.astype(cache["k"].dtype),
                           cache["k"].reshape(b, S, hkv, dh))
            vc = jnp.where(onehot[..., None, None],
                           v.astype(cache["v"].dtype),
                           cache["v"].reshape(b, S, hkv, dh))
            new_cache = {"k": kc.reshape(b, S, hkv * dh),
                         "v": vc.reshape(b, S, hkv * dh)}
        o = decode_attention(q, kc, vc, pos)
    elif cfg.attn_impl == "pallas":
        from repro.kernels import flash_attention as fk
        o = fk.flash_attention(q, k, v, causal=True)
    elif cfg.attn_impl == "ref":
        o = ops.attention(q, k, v, causal=True, impl="xla")
    else:
        o = chunked_causal_attention(q, k, v, chunk=cfg.attn_chunk)
    o = constrain(o, "act_batch", "act_seq", "act_heads", None)
    out = dense(p["wo"], o.reshape(b, l, hq * dh), cdt)
    if return_kv and cache is None:
        new_cache = {"k": k.reshape(b, l, hkv * dh),
                     "v": v.reshape(b, l, hkv * dh)}
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(cfg, key, d_ff=None, d_in=None):
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    ks = _split(key, 3)
    if cfg.mlp == "swiglu":
        return {"w1": dense_init(ks[0], d, f, ("embed", "ffn")),
                "w3": dense_init(ks[1], d, f, ("embed", "ffn")),
                "w2": dense_init(ks[2], f, d, ("ffn", "embed"))}
    return {"w1": dense_init(ks[0], d, f, ("embed", "ffn")),
            "w2": dense_init(ks[2], f, d, ("ffn", "embed"))}


def mlp_apply(cfg, p, x):
    cdt = x.dtype
    if cfg.mlp == "swiglu":
        h = approx.get_silu(cfg.silu_impl)(dense(p["w1"], x, cdt))
        h = h * dense(p["w3"], x, cdt)
    else:
        h = jax.nn.gelu(dense(p["w1"], x, cdt))
    h = constrain(h, "act_batch", "act_seq", "act_ffn")
    return dense(p["w2"], h, cdt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(cfg, key):
    p = {"tok": Param(
        jax.random.normal(key, (cfg.vocab, cfg.d_model), jnp.float32) * 0.02,
        ("vocab", "embed"))}
    return p


def embed_apply(cfg, p, tokens, dtype):
    return p["tok"].astype(dtype)[tokens]


def unembed_init(cfg, key):
    if cfg.tie_embeddings:
        return {}
    return {"w": Param(
        jax.random.normal(key, (cfg.d_model, cfg.vocab), jnp.float32)
        * cfg.d_model ** -0.5, ("embed", "vocab"))}


def unembed_apply(cfg, p, embed_p, x):
    if cfg.tie_embeddings:
        w = embed_p["tok"].T
    else:
        w = p["w"]
    ldt = jnp.dtype(cfg.logits_dtype)
    logits = jnp.einsum("bld,dv->blv", x.astype(ldt), w.astype(ldt),
                        preferred_element_type=jnp.float32).astype(ldt)
    return constrain(logits, "act_batch", "act_seq", "act_vocab")
