"""Model zoo: pure-JAX functional models (params = pytrees of
``parallel.sharding.Param``), scan-over-layers, logical-axis sharding."""
