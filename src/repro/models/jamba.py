"""Jamba (arXiv:2403.19887): Mamba + attention interleaved 1:7, MoE every
other layer.  28/32 layers are Mamba blocks -> MARCA's technique is on the
hot path (see DESIGN.md §5).

Layer stack = lax.scan over groups of ``attn_every`` layers (the repeating
pattern), params stacked on a leading "layers" (=group) dim: small HLO and
FSDP per-group weight gathers.  Pattern within a group (attn_every=8,
moe_every=2, moe_offset=1, attn_offset=4):

  pos: 0      1        2      3        4       5        6      7
       mamba  mamba    mamba  mamba    attn    mamba    mamba  mamba
       dense  MoE      dense  MoE      dense   MoE      dense  MoE
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import state_quant
from repro.models import blocks, mamba, moe
from repro.parallel.sharding import Param, constrain


def _pos_kind(cfg, pos):
    is_attn = (cfg.attn_every > 0
               and pos % cfg.attn_every == cfg.attn_offset % cfg.attn_every)
    is_moe = (cfg.is_moe and cfg.moe_every > 0
              and pos % cfg.moe_every == cfg.moe_offset % cfg.moe_every)
    return is_attn, is_moe


def _sublayer_init(cfg, key, pos):
    is_attn, is_moe = _pos_kind(cfg, pos)
    ks = jax.random.split(key, 4)
    p = {"norm1": blocks.norm_init(cfg, ks[0]),
         "norm2": blocks.norm_init(cfg, ks[1])}
    if is_attn:
        p["attn"] = blocks.attention_init(cfg, ks[2])
    else:
        p["mamba"] = mamba.mamba_block_init(cfg, ks[2])
    if is_moe:
        p["moe"] = moe.moe_init(cfg, ks[3])
    else:
        p["mlp"] = blocks.mlp_init(cfg, ks[3])
    return p


def _sublayer_apply(cfg, p, pos, x, positions, state=None, dpos=None):
    """state: mamba state dict or kv-cache dict for this sublayer."""
    is_attn, is_moe = _pos_kind(cfg, pos)
    xn = blocks.apply_norm(cfg, p["norm1"], x)
    new_state = None
    if is_attn:
        h, new_state = blocks.attention_apply(cfg, p["attn"], xn, positions,
                                              cache=state, pos=dpos)
    else:
        h, new_state = mamba.mamba_block_apply(cfg, p["mamba"], xn,
                                               state=state) \
            if dpos is None else mamba.mamba_block_step(
                cfg, p["mamba"], xn, state)
    x = x + h
    xn = blocks.apply_norm(cfg, p["norm2"], x)
    aux = {"moe_lb": jnp.float32(0), "moe_z": jnp.float32(0)}
    if is_moe:
        hm, aux = moe.moe_apply(cfg, p["moe"], xn)
    else:
        hm = blocks.mlp_apply(cfg, p["mlp"], xn)
    x = x + hm
    return constrain(x, "act_batch", "act_seq", "act_embed"), new_state, aux


def sublayer_verify(cfg, p, pos, x, state):
    """K-token verify pass for one jamba sublayer (speculative decode).

    Mamba positions (7/8 of the stack) get the real micro-scan:
    front-end batched over the K-token window, SSM recurrence as a
    K-step scan of the fused decode step with every intermediate state
    returned (mamba.mamba_block_verify); the MLP/MoE half is
    position-wise and batches trivially.  Attention positions need a
    K-wide cache-window attention (K kv writes + causal-within-window
    masking) that does not exist yet — they raise, and the engine's
    verify path for jamba chains the per-token decode_step instead.

    Returns (x_out (b, K, d), states stacked per step on axis 1)."""
    is_attn, is_moe = _pos_kind(cfg, pos)
    if is_attn:
        raise NotImplementedError(
            "jamba attention sublayers have no K-token verify window; "
            "use the chained per-token verify (registry.verify_scan)")
    xn = blocks.apply_norm(cfg, p["norm1"], x)
    h, states = mamba.mamba_block_verify(cfg, p["mamba"], xn, state)
    x = x + h
    xn = blocks.apply_norm(cfg, p["norm2"], x)
    if is_moe:
        hm, _ = moe.moe_apply(cfg, p["moe"], xn)
    else:
        hm = blocks.mlp_apply(cfg, p["mlp"], xn)
    return x + hm, states


def init(cfg, key):
    period = cfg.attn_every or 8
    assert cfg.n_layers % period == 0
    n_groups = cfg.n_layers // period
    ks = jax.random.split(key, 3)
    group_keys = jax.random.split(ks[0], n_groups)
    positions_p = {}
    for pos in range(period):
        def one(k, _pos=pos):
            return _sublayer_init(cfg, jax.random.fold_in(k, _pos), _pos)
        stacked = jax.vmap(one)(group_keys)
        positions_p[f"pos{pos}"] = jax.tree.map(
            lambda q: Param(q.value, ("layers",) + q.axes), stacked,
            is_leaf=lambda q: isinstance(q, Param))
    return {
        "embed": blocks.embed_init(cfg, ks[1]),
        "groups": positions_p,
        "norm_f": blocks.norm_init(cfg, key),
        "unembed": blocks.unembed_init(cfg, ks[2]),
    }


def forward(cfg, p, batch):
    dtype = jnp.dtype(cfg.dtype)
    period = cfg.attn_every or 8
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    h = constrain(h, "act_batch", "act_seq", "act_embed")
    b, l = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(l)[None], (b, l))
    stacked = {k: v for k, v in p["groups"].items()}

    def body(x, group_params):
        aux = {"moe_lb": jnp.float32(0), "moe_z": jnp.float32(0)}
        for pos in range(period):
            x, _, a = _sublayer_apply(cfg, group_params[f"pos{pos}"], pos,
                                      x, positions)
            aux = jax.tree.map(jnp.add, aux, a)
        return x, aux

    if cfg.remat:
        body = jax.checkpoint(body)
    h, auxs = jax.lax.scan(body, h, stacked)
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    return logits, jax.tree.map(jnp.sum, auxs)


def init_cache(cfg, batch, max_seq, dtype):
    """Per-position stacked-over-group caches: kv for attn positions,
    (h, conv) mamba state otherwise."""
    period = cfg.attn_every or 8
    n_groups = cfg.n_layers // period
    caches = {}
    for pos in range(period):
        is_attn, _ = _pos_kind(cfg, pos)
        if is_attn:
            hkv, dh = cfg.n_kv_heads, cfg.head_dim
            shape = (n_groups, batch, max_seq, hkv * dh)
            axes = ("layers", "act_batch", "act_seq", "act_ffn")
            if cfg.kv_cache_dtype == "int8":
                # int8 KV strips with per-(slot, position) absmax
                # scales living next to the payload — same
                # payload+scale-move-together contract as the
                # quantized recurrent state (state_dtype)
                sshape = (n_groups, batch, max_seq, 1)
                saxes = ("layers", "act_batch", "act_seq", None)
                caches[f"pos{pos}"] = {
                    "k": Param(jnp.zeros(shape, jnp.int8), axes),
                    "v": Param(jnp.zeros(shape, jnp.int8), axes),
                    "k_scale": Param(jnp.zeros(sshape, jnp.float32),
                                     saxes),
                    "v_scale": Param(jnp.zeros(sshape, jnp.float32),
                                     saxes)}
            else:
                caches[f"pos{pos}"] = {
                    "k": Param(jnp.zeros(shape, dtype), axes),
                    "v": Param(jnp.zeros(shape, dtype), axes)}
        else:
            di, n, k = cfg.d_inner, cfg.d_state, cfg.d_conv
            mc = {
                "h": Param(jnp.zeros(
                    (n_groups, batch, di, n),
                    state_quant.storage_dtype(cfg.state_dtype)),
                    ("layers", "act_batch", "act_ffn", None)),
                "conv": Param(jnp.zeros((n_groups, batch, k - 1, di), dtype),
                              ("layers", "act_batch", None, "act_ffn"))}
            if state_quant.is_quantized(cfg.state_dtype):
                mc["h_scale"] = Param(
                    jnp.zeros((n_groups, batch, state_quant.n_groups(di)),
                              jnp.float32),
                    ("layers", "act_batch", None))
            caches[f"pos{pos}"] = mc
    return {"layers": caches,
            "pos": Param(jnp.zeros((batch,), jnp.int32), ("act_batch",))}


def cache_slot_axes(cfg):
    """Batch/slot axis index per cache leaf (layout matches init_cache)."""
    period = cfg.attn_every or 8
    mamba_ax = {"h": 1, "conv": 1}
    if state_quant.is_quantized(cfg.state_dtype):
        mamba_ax["h_scale"] = 1
    attn_ax = {"k": 1, "v": 1}
    if cfg.kv_cache_dtype == "int8":
        attn_ax.update({"k_scale": 1, "v_scale": 1})
    caches = {}
    for pos in range(period):
        is_attn, _ = _pos_kind(cfg, pos)
        caches[f"pos{pos}"] = (dict(attn_ax) if is_attn
                               else dict(mamba_ax))
    return {"layers": caches, "pos": 0}


# ---------------------------------------------------------------------------
# Self-speculative draft views.  Jamba's layer stack is grouped (period =
# attn_every layers per group), so the draft granularity is whole groups:
# ``n`` must be a multiple of the period, and the slice keeps each
# group's internal mamba/attn/moe pattern intact.
# ---------------------------------------------------------------------------

def _n_draft_groups(cfg, n):
    period = cfg.attn_every or 8
    if n % period or not (0 < n <= cfg.n_layers):
        raise ValueError(
            f"jamba draft layers must be a multiple of the group period "
            f"({period}) in (0, {cfg.n_layers}]; got {n}")
    return n // period


def draft_params(cfg, p, n):
    ng = _n_draft_groups(cfg, n)
    groups = {k: jax.tree.map(lambda q: q[:ng], v)
              for k, v in p["groups"].items()}
    return {**p, "groups": groups}


def draft_cache(cfg, cache, n):
    ng = _n_draft_groups(cfg, n)
    layers = {k: jax.tree.map(lambda q: q[:ng], v)
              for k, v in cache["layers"].items()}
    return {"layers": layers, "pos": cache["pos"]}


def draft_cache_merge(cfg, full, sub, n):
    ng = _n_draft_groups(cfg, n)
    layers = {k: jax.tree.map(lambda f, s: f.at[:ng].set(s), v,
                              sub["layers"][k])
              for k, v in full["layers"].items()}
    return {"layers": layers, "pos": sub["pos"]}


def _megakernel_plan(cfg):
    """Static decode plan for the megakernel path: the period split into
    maximal runs of consecutive positions that are pure SSM (no
    attention, no MoE) — each run is one Pallas launch — with the
    excluded positions staying on their per-sublayer path.

    Attention is excepted by design (the kv cache window is not a
    per-layer recurrent state).  MoE is excluded because its routing
    is cross-slot (capacity competition couples the batch) and its
    expert gather does not fit a one-block kernel; a MoE-heavy config
    therefore degrades to singleton runs between MoE positions."""
    period = cfg.attn_every or 8
    plan, cur = [], []
    for pos in range(period):
        is_attn, is_moe = _pos_kind(cfg, pos)
        if is_attn or is_moe:
            if cur:
                plan.append(("mega", tuple(cur)))
                cur = []
            plan.append(("one", pos))
        else:
            cur.append(pos)
    if cur:
        plan.append(("mega", tuple(cur)))
    return tuple(plan)


def stacked_step(cfg, p, cache, batch):
    """Single-token decode with each homogeneous SSM run as ONE Pallas
    launch (see _megakernel_plan).  Same group lax.scan as decode_step;
    within a group the runs' per-position params/caches are restacked
    onto a leading run axis and handed to the megakernel, whose grid
    step does norm1 -> mamba megastep -> residual -> norm2 -> MLP ->
    residual for one position."""
    from repro.kernels import decode_step as dsk
    dtype = jnp.dtype(cfg.dtype)
    dpos = cache["pos"]
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    positions = dpos[:, None]
    plan = _megakernel_plan(cfg)
    quant = state_quant.is_quantized(cfg.state_dtype)
    b = h.shape[0]
    di, n, kc = cfg.d_inner, cfg.d_state, cfg.d_conv
    storage = state_quant.storage_dtype(cfg.state_dtype)

    def run_mega(x, group_params, group_cache, run):
        stacked_in = {
            "p": jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[group_params[f"pos{i}"] for i in run]),
            "s": jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[group_cache[f"pos{i}"] for i in run]),
        }

        def body(x, ins):
            lp = ins["p"]
            xn = blocks.apply_norm(cfg, lp["norm1"], x)
            y, ns = mamba.mamba_block_megastep(cfg, lp["mamba"], xn,
                                               ins["s"])
            x = x + y
            xn = blocks.apply_norm(cfg, lp["norm2"], x)
            x = x + blocks.mlp_apply(cfg, lp["mlp"], xn)
            x = constrain(x, "act_batch", "act_seq", "act_embed")
            outs = [ns["h"]]
            if quant:
                outs.append(ns["h_scale"])
            outs.append(ns["conv"])
            return x, outs

        conv_dtype = group_cache[f"pos{run[0]}"]["conv"].dtype
        out_structs = [jax.ShapeDtypeStruct((b, di, n), storage)]
        if quant:
            out_structs.append(jax.ShapeDtypeStruct(
                (b, state_quant.n_groups(di)), jnp.float32))
        out_structs.append(
            jax.ShapeDtypeStruct((b, kc - 1, di), conv_dtype))
        x, outs = dsk.stacked_layer_launch(
            body, x, stacked_in, out_structs,
            name="marca_megakernel_jamba")
        if quant:
            nh, nscale, nc = outs
        else:
            nh, nc = outs
        new = {}
        for j, i in enumerate(run):
            mc = {"h": nh[j], "conv": nc[j]}
            if quant:
                mc["h_scale"] = nscale[j]
            new[f"pos{i}"] = mc
        return x, new

    def body(x, inp):
        group_params, group_cache = inp
        new_cache = {}
        for kind, seg in plan:
            if kind == "mega":
                x, new = run_mega(x, group_params, group_cache, seg)
                new_cache.update(new)
            else:
                x, ns, _ = _sublayer_apply(
                    cfg, group_params[f"pos{seg}"], seg, x, positions,
                    state=group_cache[f"pos{seg}"], dpos=dpos)
                new_cache[f"pos{seg}"] = ns
        return x, new_cache

    stacked = {key: v for key, v in p["groups"].items()}
    h, new_layer_cache = jax.lax.scan(body, h, (stacked, cache["layers"]))
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    return logits, {"layers": new_layer_cache, "pos": dpos + 1}


def decode_step(cfg, p, cache, batch):
    from repro.core.selective_scan import resolve_step_impl
    if resolve_step_impl(cfg.step_impl) == "megakernel":
        return stacked_step(cfg, p, cache, batch)
    dtype = jnp.dtype(cfg.dtype)
    period = cfg.attn_every or 8
    dpos = cache["pos"]
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    positions = dpos[:, None]
    stacked = {k: v for k, v in p["groups"].items()}

    def body(x, inp):
        group_params, group_cache = inp
        new_cache = {}
        for pos in range(period):
            x, ns, _ = _sublayer_apply(cfg, group_params[f"pos{pos}"], pos,
                                       x, positions,
                                       state=group_cache[f"pos{pos}"],
                                       dpos=dpos)
            new_cache[f"pos{pos}"] = ns
        return x, new_cache

    h, new_layer_cache = jax.lax.scan(body, h, (stacked, cache["layers"]))
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    return logits, {"layers": new_layer_cache, "pos": dpos + 1}


def verify_window(cfg, p, cache, tokens):
    """Spec-decode verify over a K-token window.  Pure-SSM positions go
    through the batched ``sublayer_verify`` front-end (whole-window
    projections + SSM micro-scan); attention sublayers — which need
    K sequential kv-cache writes — and MoE sublayers — whose routing
    couples the batch through expert capacity — stay on the chained
    per-token sublayer so the produced bits match the chained
    verify_scan exactly.  Returns (logits (b, K, V), caches) in the
    chained layout (leading per-step axis)."""
    dtype = jnp.dtype(cfg.dtype)
    period = cfg.attn_every or 8
    K = tokens.shape[1]
    dpos = cache["pos"]
    x = blocks.embed_apply(cfg, p["embed"], tokens, dtype)

    def body(x, inp):
        group_params, group_cache = inp
        new_cache = {}
        for pos in range(period):
            is_attn, is_moe = _pos_kind(cfg, pos)
            gp = group_params[f"pos{pos}"]
            gc = group_cache[f"pos{pos}"]
            if is_attn or is_moe:
                xts, states = [], []
                st = gc
                for t in range(K):
                    xt, st, _ = _sublayer_apply(
                        cfg, gp, pos, x[:, t:t + 1],
                        (dpos + t)[:, None], state=st, dpos=dpos + t)
                    xts.append(xt)
                    states.append(st)
                x = jnp.concatenate(xts, axis=1)
                new_cache[f"pos{pos}"] = jax.tree.map(
                    lambda *ts: jnp.stack(ts), *states)
            else:
                x, states = sublayer_verify(cfg, gp, pos, x, gc)
                new_cache[f"pos{pos}"] = jax.tree.map(
                    lambda t: jnp.moveaxis(t, 1, 0), states)
        return x, new_cache

    stacked = {k: v for k, v in p["groups"].items()}
    x, new_layers = jax.lax.scan(body, x, (stacked, cache["layers"]))
    # scan stacks G leading over the per-step-leading leaves:
    # (G, K, b, ...) -> the chained layout (K, G, b, ...)
    new_layers = jax.tree.map(lambda t: t.swapaxes(0, 1), new_layers)
    x = blocks.apply_norm(cfg, p["norm_f"], x)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], x)
    pos = (dpos[None, :]
           + jnp.arange(1, K + 1, dtype=jnp.int32)[:, None])
    return logits, {"layers": new_layers, "pos": pos}


def prefill(cfg, p, cache, batch):
    """Full-sequence forward filling kv caches (attn positions) and mamba
    states (others).  cache supplies max_seq capacity."""
    dtype = jnp.dtype(cfg.dtype)
    period = cfg.attn_every or 8
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    h = constrain(h, "act_batch", "act_seq", "act_embed")
    b, l = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(l)[None], (b, l))
    S = None
    for pos in range(period):
        is_attn, _ = _pos_kind(cfg, pos)
        if is_attn:
            S = cache["layers"][f"pos{pos}"]["k"].shape[2]

    def body(x, group_params):
        new_cache = {}
        for pos in range(period):
            is_attn, _ = _pos_kind(cfg, pos)
            xn = blocks.apply_norm(
                cfg, group_params[f"pos{pos}"]["norm1"], x)
            if is_attn:
                hh, kv = blocks.attention_apply(
                    cfg, group_params[f"pos{pos}"]["attn"], xn, positions,
                    return_kv=True)
                pad = S - l

                def _p(t):
                    return jnp.pad(t, ((0, 0), (0, pad), (0, 0)))

                if cfg.kv_cache_dtype == "int8":
                    kq, ks = blocks._kv_quant(kv["k"])
                    vq, vs = blocks._kv_quant(kv["v"])
                    new_cache[f"pos{pos}"] = {
                        "k": _p(kq), "v": _p(vq),
                        "k_scale": _p(ks), "v_scale": _p(vs)}
                else:
                    new_cache[f"pos{pos}"] = {"k": _p(kv["k"]),
                                              "v": _p(kv["v"])}
            else:
                hh, ns = mamba.mamba_block_apply(
                    cfg, group_params[f"pos{pos}"]["mamba"], xn)
                mc = {"h": ns["h"], "conv": ns["conv"].astype(dtype)}
                if "h_scale" in ns:        # quantized state_dtype
                    mc["h_scale"] = ns["h_scale"]
                new_cache[f"pos{pos}"] = mc
            x = x + hh
            xn = blocks.apply_norm(
                cfg, group_params[f"pos{pos}"]["norm2"], x)
            _, is_moe = _pos_kind(cfg, pos)
            if is_moe:
                hm, _ = moe.moe_apply(cfg, group_params[f"pos{pos}"]["moe"],
                                      xn)
            else:
                hm = blocks.mlp_apply(cfg, group_params[f"pos{pos}"]["mlp"],
                                      xn)
            x = x + hm
            x = constrain(x, "act_batch", "act_seq", "act_embed")
        return x, new_cache

    stacked = {k: v for k, v in p["groups"].items()}
    h, new_layer_cache = jax.lax.scan(body, h, stacked)
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    return logits, {"layers": new_layer_cache,
                    "pos": jnp.full((b,), l, jnp.int32)}
