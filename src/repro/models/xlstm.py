"""xLSTM (Beck et al. 2024, arXiv:2405.04517): mLSTM + sLSTM blocks.

This is the second SSM-family arch MARCA's insights apply to: both
recurrences are element-wise chains (the Fig. 1 regime), so the same
chunked-state-residency treatment as selective_scan is used — lax.scan over
chunks with jax.checkpoint inside, state (C, n, m) carried across chunks.

Simplifications vs the reference implementation (documented per DESIGN.md):
per-head q/k/v projections are dense (nh, dh, dh) einsums (block-diagonal in
the original), the mLSTM block uses pf=2 up-projection with a SiLU-gated
residual path, and sLSTM uses a single round of gate recurrence per step.
Exp/sigmoid gates run through cfg.exp_impl / MARCA piecewise sigmoid when
approx mode is on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import state_quant
from repro.core import approx
from repro.models import blocks
from repro.parallel.sharding import Param, constrain


def _gates(cfg):
    exp = approx.get_exp(cfg.exp_impl)
    sig = (approx.piecewise_sigmoid if cfg.exp_impl != "exact"
           else jax.nn.sigmoid)
    return exp, sig


# ---------------------------------------------------------------------------
# mLSTM: matrix memory C (dh x dh) per head, parallelizable recurrence
# ---------------------------------------------------------------------------

def mlstm_block_init(cfg, key):
    d, nh = cfg.d_model, cfg.n_heads
    di = 2 * d                       # pf = 2 up-projection
    dh = di // nh
    ks = jax.random.split(key, 8)
    sc = dh ** -0.5

    def ph(k, shape, axes):
        return Param(jax.random.normal(k, shape, jnp.float32) * sc, axes)

    return {
        "norm": blocks.norm_init(cfg, ks[0]),
        "up": blocks.dense_init(ks[1], d, 2 * di, ("embed", "ffn")),
        "conv_w": Param(jax.random.normal(ks[2], (cfg.d_conv, di),
                                          jnp.float32) / cfg.d_conv,
                        ("conv", "ffn")),
        "wq": ph(ks[3], (nh, dh, dh), ("heads", None, None)),
        "wk": ph(ks[4], (nh, dh, dh), ("heads", None, None)),
        "wi": ph(ks[5], (nh, dh), ("heads", None)),
        "wf": ph(ks[6], (nh, dh), ("heads", None)),
        "bi": Param(jnp.zeros((nh,), jnp.float32), ("heads",)),
        "bf": Param(jnp.full((nh,), 3.0, jnp.float32), ("heads",)),
        "gn_scale": Param(jnp.ones((di,), jnp.float32), ("ffn",)),
        "down": blocks.dense_init(ks[7], di, d, ("ffn", "embed")),
    }


def _mlstm_cell(C, n, m, q_t, k_t, v_t, i_t, f_t, dh):
    """One stabilized mLSTM recurrence step — the single source of truth
    shared by the chunked scan body, the fused decode step, AND the
    megakernel body: the math lives in the kernels' cell skeleton
    (kernels.decode_step.mlstm_cell), this wrapper just adapts the
    historical signature.  All inputs f32; (b,nh,...) layouts."""
    from repro.kernels import decode_step as dsk
    h_t, state_new = dsk.mlstm_cell(dh)(
        (C, n, m), {"q": q_t, "k": k_t, "v": v_t, "i": i_t, "f": f_t})
    return state_new, h_t


def _mlstm_scan(q, k, v, ig, fg, state, chunk, remat=True):
    """Stabilized mLSTM recurrence.
    q/k/v (b, L, nh, dh); ig/fg (b, L, nh) pre-activation gates.
    state: dict C (b,nh,dh,dh), n (b,nh,dh), m (b,nh).  Chunked lax.scan."""
    b, L, nh, dh = q.shape
    chunk = max(1, min(chunk, L))
    pad = (-L) % chunk
    nc = (L + pad) // chunk

    def _p(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))

    def _r(t):
        return _p(t).reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    qs, ks_, vs = _r(q.astype(jnp.float32)), _r(k.astype(jnp.float32)), \
        _r(v.astype(jnp.float32))
    igs, fgs = _r(ig.astype(jnp.float32)), _r(fg.astype(jnp.float32))
    # padded steps: fg pre-activation large -> f ~ 1, i -> 0 keeps state
    if pad:
        mask = jnp.arange(nc * chunk).reshape(nc, chunk) < L
        mask = mask[:, None, :, None]                    # (nc,1,chunk,1)
        igs = jnp.where(mask, igs, -1e30)
        fgs = jnp.where(mask, fgs, 30.0)

    def step(carry, inp):
        C, n, m = carry
        q_t, k_t, v_t, i_t, f_t = inp                    # (b,nh,dh) ...
        return _mlstm_cell(C, n, m, q_t, k_t, v_t, i_t, f_t, dh)

    def chunk_body(carry, inp):
        qc, kc, vc, ic, fc = inp                         # (b,chunk,nh,..)
        xs = tuple(t.swapaxes(0, 1) for t in (qc, kc, vc, ic, fc))

        def inner(carry):
            return jax.lax.scan(step, carry, xs)

        if remat:
            inner = jax.checkpoint(inner)
        carry, hs = inner(carry)
        return carry, hs.swapaxes(0, 1)                  # (b,chunk,nh,dh)

    carry0 = (state["C"], state["n"], state["m"])
    carry, hs = jax.lax.scan(chunk_body, carry0, (qs, ks_, vs, igs, fgs))
    h = hs.swapaxes(0, 1).reshape(b, nc * chunk, nh, dh)[:, :L]
    new_state = {"C": carry[0], "n": carry[1], "m": carry[2]}
    return h, new_state


def _mlstm_inputs(cfg, p, x, conv_state, conv_impl=None):
    """Block front-end shared by apply (L=seq) and the decode step (L=1):
    norm -> up-proj -> short conv -> SiLU -> q/k/v projections + gate
    pre-activations.  One source of truth so the two paths cannot drift.
    ``conv_impl`` overrides cfg.conv_impl (the megakernel body forces
    "xla" — a Pallas kernel cannot nest another launch)."""
    d, nh = cfg.d_model, cfg.n_heads
    di = 2 * d
    dh = di // nh
    b, L, _ = x.shape
    silu = approx.get_silu(cfg.silu_impl)
    xn = blocks.apply_norm(cfg, p["norm"], x)
    ug = blocks.dense(p["up"], xn, x.dtype)
    u, g = jnp.split(ug, 2, axis=-1)                     # (b,L,di) each
    u = constrain(u, "act_batch", "act_seq", "act_ffn")
    from repro.kernels import ops
    c, new_conv = ops.causal_conv1d(
        u, p["conv_w"], None, x_prev=conv_state,
        impl=conv_impl or cfg.conv_impl)
    ch = silu(c).reshape(b, L, nh, dh)
    q = jnp.einsum("blhd,hde->blhe", ch, p["wq"].astype(x.dtype))
    k = jnp.einsum("blhd,hde->blhe", ch, p["wk"].astype(x.dtype))
    v = u.reshape(b, L, nh, dh)
    chf = ch.astype(jnp.float32)
    ig = jnp.einsum("blhd,hd->blh", chf, p["wi"]) + p["bi"]
    fg = jnp.einsum("blhd,hd->blh", chf, p["wf"]) + p["bf"]
    return q, k, v, ig, fg, g, new_conv


def read_state_C(cfg, state):
    """Decode the stored matrix memory to f32.  int8/fp8 dequantizes
    with the per-(slot, head) scales in state["C_scale"]."""
    if state_quant.is_quantized(cfg.state_dtype):
        return state_quant.dequantize_mat(state["C"], state["C_scale"])
    return state["C"].astype(jnp.float32)


def write_state_C(cfg, C, prev_state=None):
    """Encode a f32 matrix memory for storage: {"C": ...} (+"C_scale").
    Only C is quantized — the normalizer n, stabilizer m, and conv tail
    are O(d) per slot vs C's O(d * dh), so they stay f32."""
    if state_quant.is_quantized(cfg.state_dtype):
        prev = None if prev_state is None else prev_state["C_scale"]
        q, scale = state_quant.quantize_mat(C, cfg.state_dtype,
                                            prev_scale=prev)
        return {"C": q, "C_scale": scale}
    return {"C": C.astype(state_quant.storage_dtype(cfg.state_dtype))}


def mlstm_block_apply(cfg, p, x, state=None):
    d, nh = cfg.d_model, cfg.n_heads
    di = 2 * d
    b, L, _ = x.shape
    silu = approx.get_silu(cfg.silu_impl)
    conv_state = None if state is None else state["conv"]
    q, k, v, ig, fg, g, new_conv = _mlstm_inputs(cfg, p, x, conv_state)
    if state is None:
        s0 = _mlstm_state(cfg, b)
        C0, n0, m0 = s0["C"], s0["n"], s0["m"]
    else:
        C0, n0, m0 = read_state_C(cfg, state), state["n"], state["m"]
    h, new_rec = _mlstm_scan(q, k, v, ig, fg,
                             {"C": C0, "n": n0, "m": m0},
                             cfg.scan_chunk, remat=cfg.remat)
    hf = blocks.group_norm(h.reshape(b, L, di), p["gn_scale"], nh)
    out = blocks.dense(p["down"], hf * silu(g), x.dtype)
    new_state = write_state_C(cfg, new_rec["C"], prev_state=state)
    new_state.update({"n": new_rec["n"], "m": new_rec["m"],
                      "conv": new_conv})
    return out, new_state


def mlstm_block_step(cfg, p, x_t, state, conv_impl=None):
    """Single-token decode: shared front-end + one _mlstm_cell step, no
    chunked-scan machinery (padding, reshapes, remat) — the per-token
    path the serving engine's decode burst dispatches.  Matches
    mlstm_block_apply at L=1.  ``conv_impl`` is the megakernel body's
    override (see _mlstm_inputs)."""
    d, nh = cfg.d_model, cfg.n_heads
    di = 2 * d
    dh = di // nh
    b = x_t.shape[0]
    silu = approx.get_silu(cfg.silu_impl)
    q, k, v, ig, fg, g, new_conv = _mlstm_inputs(
        cfg, p, x_t, state["conv"], conv_impl=conv_impl)
    qf, kf, vf = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    (C_new, n_new, m_new), h_t = _mlstm_cell(
        read_state_C(cfg, state), state["n"], state["m"], qf, kf, vf,
        ig[:, 0], fg[:, 0], dh)

    hf = blocks.group_norm(h_t.reshape(b, 1, di), p["gn_scale"], nh)
    out = blocks.dense(p["down"], hf * silu(g), x_t.dtype)
    new_state = write_state_C(cfg, C_new, prev_state=state)
    new_state.update({"n": n_new, "m": m_new, "conv": new_conv})
    return out, new_state


def mlstm_block_verify(cfg, p, x, state):
    """K-token verify pass (speculative decode): semantically K chained
    ``mlstm_block_step`` calls — front-end (norm, up-proj, conv, q/k/v,
    gates) batched over the K-token window, recurrence as a K-step scan
    of the same ``_mlstm_cell`` the decode step uses, every
    intermediate (C, n, m) returned for rollback.

    Returns (out (b, K, d), states) with state leaves stacked per step
    on axis 1 (states[t] = block state after consuming token t)."""
    from repro.models.mamba import _conv_tail_states
    d, nh = cfg.d_model, cfg.n_heads
    di = 2 * d
    dh = di // nh
    b, K, _ = x.shape
    silu = approx.get_silu(cfg.silu_impl)
    q, k, v, ig, fg, g, _ = _mlstm_inputs(cfg, p, x, state["conv"])
    conv_all = _conv_tail_states(state["conv"], v.reshape(b, K, di))
    quant = state_quant.is_quantized(cfg.state_dtype)

    def step(carry, inp):
        q_t, k_t, v_t, i_t, f_t = inp
        if quant:
            Cq, Cs, n, m = carry
            C = state_quant.dequantize_mat(Cq, Cs)
        else:
            C_st, n, m = carry
            C = C_st.astype(jnp.float32)
        (C_new, n_new, m_new), h_t = _mlstm_cell(
            C, n, m, q_t, k_t, v_t, i_t, f_t, dh)
        if quant:
            Cq_new, Cs_new = state_quant.quantize_mat(
                C_new, cfg.state_dtype, prev_scale=Cs)
            carry = (Cq_new, Cs_new, n_new, m_new)
        else:
            carry = (C_new.astype(
                state_quant.storage_dtype(cfg.state_dtype)),
                n_new, m_new)
        return carry, (carry, h_t)

    qf, kf, vf = (jnp.moveaxis(t.astype(jnp.float32), 1, 0)
                  for t in (q, k, v))
    igs, fgs = jnp.moveaxis(ig, 1, 0), jnp.moveaxis(fg, 1, 0)
    if quant:
        carry0 = (state["C"], state["C_scale"], state["n"], state["m"])
    else:
        carry0 = (state["C"], state["n"], state["m"])
    _, (stacked, hs) = jax.lax.scan(step, carry0, (qf, kf, vf, igs, fgs))
    h = jnp.moveaxis(hs, 0, 1)                        # (b,K,nh,dh)
    hf = blocks.group_norm(h.reshape(b, K, di), p["gn_scale"], nh)
    out = blocks.dense(p["down"], hf * silu(g), x.dtype)
    if quant:
        Cq_all, Cs_all, n_all, m_all = stacked
        states = {"C": jnp.moveaxis(Cq_all, 0, 1),
                  "C_scale": jnp.moveaxis(Cs_all, 0, 1),
                  "n": jnp.moveaxis(n_all, 0, 1),
                  "m": jnp.moveaxis(m_all, 0, 1), "conv": conv_all}
    else:
        C_all, n_all, m_all = stacked
        states = {"C": jnp.moveaxis(C_all, 0, 1),
                  "n": jnp.moveaxis(n_all, 0, 1),
                  "m": jnp.moveaxis(m_all, 0, 1), "conv": conv_all}
    return out, states


def _mlstm_state(cfg, batch):
    d, nh = cfg.d_model, cfg.n_heads
    di = 2 * d
    dh = di // nh
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di), jnp.float32),
    }


def mlstm_state_init(cfg, batch, dtype):
    s = _mlstm_state(cfg, batch)
    s["C"] = s["C"].astype(state_quant.storage_dtype(cfg.state_dtype))
    axes = {"C": ("act_batch", "act_heads", None, None),
            "n": ("act_batch", "act_heads", None),
            "m": ("act_batch", "act_heads"),
            "conv": ("act_batch", None, "act_ffn"),
            "C_scale": ("act_batch", "act_heads", None)}
    if state_quant.is_quantized(cfg.state_dtype):
        dh = 2 * cfg.d_model // cfg.n_heads
        s["C_scale"] = jnp.zeros((batch, cfg.n_heads, dh), jnp.float32)
    return {k: Param(v, axes[k]) for k, v in s.items()}


# ---------------------------------------------------------------------------
# sLSTM: scalar memory with true hidden-state recurrence (sequential)
# ---------------------------------------------------------------------------

def slstm_block_init(cfg, key):
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    ks = jax.random.split(key, 8)
    sc = d ** -0.5

    def ph(k, shape, axes):
        return Param(jax.random.normal(k, shape, jnp.float32) * sc, axes)

    return {
        "norm": blocks.norm_init(cfg, ks[0]),
        "wx": blocks.dense_init(ks[1], d, 4 * d, ("embed", "ffn")),
        "r": ph(ks[2], (4, nh, dh, dh), (None, "heads", None, None)),
        "b": Param(jnp.concatenate([
            jnp.zeros((2 * d,), jnp.float32),           # z, i
            jnp.full((d,), 3.0, jnp.float32),           # f
            jnp.zeros((d,), jnp.float32)]),             # o
            ("ffn",)),
        "gn_scale": Param(jnp.ones((d,), jnp.float32), ("ffn",)),
        "out": blocks.dense_init(ks[3], d, d, ("ffn", "embed")),
    }


def _slstm_cell(c, n, m, g):
    """One stabilized sLSTM gate step from combined pre-activations
    g (b,4,nh,dh) — shared by the chunked scan body, the fused decode
    step, and the megakernel body (the math lives in
    kernels.decode_step.slstm_cell).  Returns (c_new, n_new, h_new,
    m_new)."""
    from repro.kernels import decode_step as dsk
    h_new, (c_new, n_new, m_new) = dsk.slstm_cell()((c, n, m), {"g": g})
    return c_new, n_new, h_new, m_new


def _slstm_scan(gates_x, r, bias, state, nh, dh, chunk, remat=True):
    """gates_x (b, L, 4d) input contributions; recurrence adds R h_{t-1}.
    state: c,n,h (b,nh,dh), m (b,nh,dh)."""
    b, L, d4 = gates_x.shape
    d = d4 // 4
    chunk = max(1, min(chunk, L))
    pad = (-L) % chunk
    nc = (L + pad) // chunk
    gx = jnp.pad(gates_x.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    gx = gx.reshape(b, nc, chunk, d4).swapaxes(0, 1)
    valid = (jnp.arange(nc * chunk).reshape(nc, chunk) < L)

    def step(carry, inp):
        c, n, h, m = carry                               # (b,nh,dh)
        g_t, ok = inp                                    # (b,4d), ()
        rec = jnp.einsum("gher,bhe->bghr", r, h)         # (b,4,nh,dh)
        g = g_t.reshape(b, 4, nh, dh) + rec + bias.reshape(4, nh, dh)
        c_new, n_new, h_new, m_new = _slstm_cell(c, n, m, g)
        # padded steps: keep state
        keep = ok.astype(jnp.float32)
        c_new = keep * c_new + (1 - keep) * c
        n_new = keep * n_new + (1 - keep) * n
        h_new = keep * h_new + (1 - keep) * h
        m_new = keep * m_new + (1 - keep) * m
        return (c_new, n_new, h_new, m_new), h_new

    def chunk_body(carry, inp):
        gc, okc = inp

        def inner(carry):
            return jax.lax.scan(step, carry,
                                (gc.swapaxes(0, 1), okc))

        if remat:
            inner = jax.checkpoint(inner)
        carry, hs = inner(carry)
        return carry, hs.swapaxes(0, 1)

    carry0 = (state["c"], state["n"], state["h"], state["m"])
    carry, hs = jax.lax.scan(chunk_body, carry0, (gx, valid))
    h = hs.swapaxes(0, 1).reshape(b, nc * chunk, nh * dh)[:, :L]
    return h, {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}


def slstm_block_apply(cfg, p, x, state=None):
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    b, L, _ = x.shape
    xn = blocks.apply_norm(cfg, p["norm"], x)
    gates_x = blocks.dense(p["wx"], xn, x.dtype)
    if state is None:
        state = _slstm_state(cfg, b)
    h, new_state = _slstm_scan(gates_x, p["r"], p["b"], state, nh, dh,
                               cfg.scan_chunk, remat=cfg.remat)
    hf = blocks.group_norm(h, p["gn_scale"], nh)
    out = blocks.dense(p["out"], hf, x.dtype)
    return out, new_state


def slstm_block_step(cfg, p, x_t, state):
    """Single-token decode: one gate-recurrence step, no chunked-scan
    machinery.  Matches slstm_block_apply at L=1."""
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    b = x_t.shape[0]
    xn = blocks.apply_norm(cfg, p["norm"], x_t)
    gates_x = blocks.dense(p["wx"], xn, x_t.dtype)       # (b,1,4d)
    g_t = gates_x[:, 0].astype(jnp.float32)

    rec = jnp.einsum("gher,bhe->bghr", p["r"], state["h"])  # (b,4,nh,dh)
    g = g_t.reshape(b, 4, nh, dh) + rec + p["b"].reshape(4, nh, dh)
    c_new, n_new, h_new, m_new = _slstm_cell(
        state["c"], state["n"], state["m"], g)

    hf = blocks.group_norm(h_new.reshape(b, 1, d), p["gn_scale"], nh)
    out = blocks.dense(p["out"], hf, x_t.dtype)
    return out, {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_block_verify(cfg, p, x, state):
    """K-token verify pass: K chained ``slstm_block_step`` calls with the
    input-gate projections batched over the window; the hidden-state
    recurrence (R h_{t-1}) is inherently sequential and runs in the
    scan.  Returns (out (b, K, d), states) with per-step (c, n, h, m)
    stacked on axis 1."""
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    b, K, _ = x.shape
    xn = blocks.apply_norm(cfg, p["norm"], x)
    gates_x = blocks.dense(p["wx"], xn, x.dtype)          # (b,K,4d)

    def step(carry, g_t):
        c, n, h, m = carry
        rec = jnp.einsum("gher,bhe->bghr", p["r"], h)
        g = (g_t.reshape(b, 4, nh, dh) + rec
             + p["b"].reshape(4, nh, dh))
        c_new, n_new, h_new, m_new = _slstm_cell(c, n, m, g)
        carry = (c_new, n_new, h_new, m_new)
        return carry, carry

    gxs = jnp.moveaxis(gates_x.astype(jnp.float32), 1, 0)
    _, stacked = jax.lax.scan(
        step, (state["c"], state["n"], state["h"], state["m"]), gxs)
    c_all, n_all, h_all, m_all = (jnp.moveaxis(t, 0, 1) for t in stacked)
    hf = blocks.group_norm(h_all.reshape(b, K, d), p["gn_scale"], nh)
    out = blocks.dense(p["out"], hf, x.dtype)
    return out, {"c": c_all, "n": n_all, "h": h_all, "m": m_all}


def _slstm_state(cfg, batch):
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"c": z, "n": z, "h": z,
            "m": jnp.full((batch, nh, dh), -1e30, jnp.float32)}


def slstm_state_init(cfg, batch, dtype):
    axes = ("act_batch", "act_heads", None)
    return {k: Param(v, axes) for k, v in _slstm_state(cfg, batch).items()}


# ---------------------------------------------------------------------------
# Full model: interleave mLSTM / sLSTM (7:1 by default)
# ---------------------------------------------------------------------------

def _is_slstm(cfg, i):
    return (cfg.slstm_every > 0
            and i % cfg.slstm_every == cfg.slstm_offset % cfg.slstm_every)


def init(cfg, key):
    ks = jax.random.split(key, cfg.n_layers + 3)
    layers = []
    for i in range(cfg.n_layers):
        if _is_slstm(cfg, i):
            layers.append({"slstm": slstm_block_init(cfg, ks[i])})
        else:
            layers.append({"mlstm": mlstm_block_init(cfg, ks[i])})
    return {
        "embed": blocks.embed_init(cfg, ks[-3]),
        "layers": layers,
        "norm_f": blocks.norm_init(cfg, ks[-2]),
        "unembed": blocks.unembed_init(cfg, ks[-1]),
    }


def forward(cfg, p, batch):
    dtype = jnp.dtype(cfg.dtype)
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    h = constrain(h, "act_batch", "act_seq", "act_embed")
    for i, lp in enumerate(p["layers"]):
        if "slstm" in lp:
            y, _ = slstm_block_apply(cfg, lp["slstm"], h)
        else:
            y, _ = mlstm_block_apply(cfg, lp["mlstm"], h)
        h = h + y
        h = constrain(h, "act_batch", "act_seq", "act_embed")
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    return logits, {}


def init_cache(cfg, batch, max_seq, dtype):
    caches = []
    for i in range(cfg.n_layers):
        if _is_slstm(cfg, i):
            caches.append({"slstm": slstm_state_init(cfg, batch, dtype)})
        else:
            caches.append({"mlstm": mlstm_state_init(cfg, batch, dtype)})
    return {"layers": caches,
            "pos": Param(jnp.zeros((batch,), jnp.int32), ("act_batch",))}


def cache_slot_axes(cfg):
    """Batch/slot axis index per cache leaf (layout matches init_cache):
    all xLSTM state tensors are batch-leading."""
    mlstm_keys = ["C", "n", "m", "conv"]
    if state_quant.is_quantized(cfg.state_dtype):
        mlstm_keys.append("C_scale")
    layers = []
    for i in range(cfg.n_layers):
        if _is_slstm(cfg, i):
            layers.append({"slstm": {k: 0 for k in ("c", "n", "h", "m")}})
        else:
            layers.append({"mlstm": {k: 0 for k in mlstm_keys}})
    return {"layers": layers, "pos": 0}


# ---------------------------------------------------------------------------
# Self-speculative draft views (layers is a python list, so a draft is a
# list slice; the mLSTM/sLSTM interleave pattern of the first n layers
# is preserved because _is_slstm is index-based).
# ---------------------------------------------------------------------------

def draft_params(cfg, p, n):
    return {**p, "layers": p["layers"][:n]}


def draft_cache(cfg, cache, n):
    return {"layers": cache["layers"][:n], "pos": cache["pos"]}


def draft_cache_merge(cfg, full, sub, n):
    return {"layers": list(sub["layers"]) + list(full["layers"][n:]),
            "pos": sub["pos"]}


def _kind_runs(cfg):
    """Maximal runs of consecutive same-kind layers — each run is one
    megakernel launch (the kernel grid needs a homogeneous cell and
    uniform state shapes across its layer axis)."""
    runs, cur, cur_kind = [], [], None
    for i in range(cfg.n_layers):
        kind = "slstm" if _is_slstm(cfg, i) else "mlstm"
        if kind != cur_kind and cur:
            runs.append((cur_kind, tuple(cur)))
            cur = []
        cur_kind = kind
        cur.append(i)
    if cur:
        runs.append((cur_kind, tuple(cur)))
    return tuple(runs)


def stacked_step(cfg, p, cache, batch):
    """Single-token decode with each homogeneous layer run as ONE Pallas
    launch — xLSTM's first fused decode path, obtained for free from the
    cell skeleton: the per-layer step functions are already pure XLA, so
    they trace directly as the megakernel body (mLSTM forcing the "xla"
    conv inside the kernel).  A pure-mLSTM stack is exactly one launch
    per token; an interleaved stack gets one launch per run."""
    from repro.kernels import decode_step as dsk
    dtype = jnp.dtype(cfg.dtype)
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    quant = state_quant.is_quantized(cfg.state_dtype)
    new_layers = [None] * cfg.n_layers
    for kind, run in _kind_runs(cfg):
        stacked_in = {
            "p": jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[p["layers"][i][kind] for i in run]),
            "s": jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[cache["layers"][i][kind] for i in run]),
        }
        if kind == "mlstm":
            keys = (["C"] + (["C_scale"] if quant else [])
                    + ["n", "m", "conv"])

            def body(x, ins, _keys=keys):
                y, ns = mlstm_block_step(cfg, ins["p"], x, ins["s"],
                                         conv_impl="xla")
                return x + y, [ns[k] for k in _keys]
        else:
            keys = ["c", "n", "h", "m"]

            def body(x, ins, _keys=keys):
                y, ns = slstm_block_step(cfg, ins["p"], x, ins["s"])
                return x + y, [ns[k] for k in _keys]

        s0 = cache["layers"][run[0]][kind]
        out_structs = [jax.ShapeDtypeStruct(s0[k].shape, s0[k].dtype)
                       for k in keys]
        h, outs = dsk.stacked_layer_launch(
            body, h, stacked_in, out_structs,
            name=f"marca_megakernel_{kind}")
        for j, i in enumerate(run):
            new_layers[i] = {kind: {k: outs[jj][j]
                                    for jj, k in enumerate(keys)}}
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    return logits, {"layers": new_layers, "pos": cache["pos"] + 1}


def decode_step(cfg, p, cache, batch):
    """Per-token path.  cfg.step_impl routes the recurrences:
    "megakernel" runs each homogeneous layer run as one Pallas launch
    (stacked_step); "fused" (the pre-megakernel "auto" default — xLSTM's
    fused step is pure XLA, so it wins on every backend) takes the
    dedicated single-step functions per layer; "xla" keeps the L=1
    chunked-apply path as the parity reference."""
    from repro.core.selective_scan import resolve_step_impl
    impl = resolve_step_impl(cfg.step_impl, needs_pallas=False)
    if impl == "megakernel":
        return stacked_step(cfg, p, cache, batch)
    fused = impl == "fused"
    dtype = jnp.dtype(cfg.dtype)
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    new_layers = []
    for i, (lp, lc) in enumerate(zip(p["layers"], cache["layers"])):
        if "slstm" in lp:
            y, ns = (slstm_block_step(cfg, lp["slstm"], h, lc["slstm"])
                     if fused else
                     slstm_block_apply(cfg, lp["slstm"], h,
                                       state=lc["slstm"]))
            new_layers.append({"slstm": ns})
        else:
            y, ns = (mlstm_block_step(cfg, lp["mlstm"], h, lc["mlstm"])
                     if fused else
                     mlstm_block_apply(cfg, lp["mlstm"], h,
                                       state=lc["mlstm"]))
            new_layers.append({"mlstm": ns})
        h = h + y
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    return logits, {"layers": new_layers, "pos": cache["pos"] + 1}


def verify_window(cfg, p, cache, tokens):
    """Spec-decode verify over a K-token window through the batched
    block front-ends (mlstm_block_verify / slstm_block_verify): the
    projections, conv, and gate pre-activations run over the whole
    window at once, only the recurrences scan.  Returns the chained
    verify_scan layout: (logits (b, K, V), caches with a leading
    per-step axis)."""
    dtype = jnp.dtype(cfg.dtype)
    K = tokens.shape[1]
    x = blocks.embed_apply(cfg, p["embed"], tokens, dtype)
    new_layers = []
    for lp, lc in zip(p["layers"], cache["layers"]):
        if "slstm" in lp:
            y, states = slstm_block_verify(cfg, lp["slstm"], x,
                                           lc["slstm"])
            kind = "slstm"
        else:
            y, states = mlstm_block_verify(cfg, lp["mlstm"], x,
                                           lc["mlstm"])
            kind = "mlstm"
        # block_verify stacks steps on axis 1 -> chained layout (K, b, ..)
        new_layers.append({kind: jax.tree.map(
            lambda t: jnp.moveaxis(t, 1, 0), states)})
        x = x + y
    x = blocks.apply_norm(cfg, p["norm_f"], x)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], x)
    pos = (cache["pos"][None, :]
           + jnp.arange(1, K + 1, dtype=jnp.int32)[:, None])
    return logits, {"layers": new_layers, "pos": pos}


def prefill(cfg, p, cache, batch):
    """Full-sequence forward collecting recurrent states (pos = seq_len)."""
    dtype = jnp.dtype(cfg.dtype)
    h = blocks.embed_apply(cfg, p["embed"], batch["tokens"], dtype)
    b, l = h.shape[:2]
    new_layers = []
    for i, lp in enumerate(p["layers"]):
        if "slstm" in lp:
            y, ns = slstm_block_apply(cfg, lp["slstm"], h)
            new_layers.append({"slstm": ns})
        else:
            y, ns = mlstm_block_apply(cfg, lp["mlstm"], h)
            new_layers.append({"mlstm": ns})
        h = h + y
    h = blocks.apply_norm(cfg, p["norm_f"], h)
    logits = blocks.unembed_apply(cfg, p.get("unembed", {}), p["embed"], h)
    return logits, {"layers": new_layers,
                    "pos": jnp.full((b,), l, jnp.int32)}
